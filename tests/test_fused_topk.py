"""Cross-engine golden parity for PR 3's two fusions.

SAAT: ``fused_topk=True`` (scatter→top-k fused in one Pallas kernel, only
``[B, blocks * k]`` candidates ever reach HBM) must be indistinguishable from
the unfused engine and the ``saat_search_vmap`` oracle — BIT-identical doc
ids (including ``-inf`` tie order on padded ranks), scores bit-identical to
the unfused Pallas scatter (same per-block accumulation order) and fp32-close
to the jnp scatters — across ragged batches, duplicate / zero-weight terms,
``k > n_docs``, and every rho on the serving ladder.

DAAT: ``use_kernels=True`` (phase 2 through ``block_prune_batched`` +
``block_topk_batched`` + ``sparse_score_batched``) must match the jnp
formulation on doc ids AND per-query :class:`WorkStats` exactly.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_impact_index,
    daat_search_batched,
    exact_rho,
    exhaustive_search,
    saat_search,
    saat_search_vmap,
)
from repro.core.daat import max_blocks_per_term
from repro.core.saat import max_segments_per_term

RHO_LADDER = (100, 500, 2000, "exact", "beyond")


def _resolve_rho(index, rho):
    if rho == "exact":
        return exact_rho(index)
    if rho == "beyond":
        return exact_rho(index) * 2
    return rho


def _assert_fused_saat_parity(index, qt, qw, *, k, rho):
    """fused == unfused-pallas (bit) == unfused-jnp == vmap oracle (ids)."""
    ms = max_segments_per_term(index)
    f = saat_search(index, qt, qw, k=k, rho=rho, max_segs_per_term=ms, fused_topk=True)
    up = saat_search(index, qt, qw, k=k, rho=rho, max_segs_per_term=ms, scatter_impl="pallas")
    uj = saat_search(index, qt, qw, k=k, rho=rho, max_segs_per_term=ms, scatter_impl="jnp")
    v = saat_search_vmap(index, qt, qw, k=k, rho=rho, max_segs_per_term=ms, scatter_impl="jnp")
    # same accumulation kernel per block -> the fusion is bit-invisible
    np.testing.assert_array_equal(np.asarray(f.doc_ids), np.asarray(up.doc_ids))
    np.testing.assert_array_equal(np.asarray(f.scores), np.asarray(up.scores))
    # jnp scatters reassociate the same sums -> ids exact, scores fp32-close
    for other in (uj, v):
        np.testing.assert_array_equal(np.asarray(f.doc_ids), np.asarray(other.doc_ids))
        np.testing.assert_allclose(
            np.asarray(f.scores), np.asarray(other.scores), rtol=1e-4, atol=1e-4
        )
        np.testing.assert_array_equal(
            np.asarray(f.postings_processed), np.asarray(other.postings_processed)
        )
    return f


def _assert_daat_kernel_parity(index, qt, qw, **kw):
    """use_kernels=True vs the jnp formulation: ids + WorkStats exact."""
    kw.setdefault("max_bm_per_term", max_blocks_per_term(index))
    kj = daat_search_batched(index, qt, qw, use_kernels=False, **kw)
    kk = daat_search_batched(index, qt, qw, use_kernels=True, **kw)
    np.testing.assert_array_equal(np.asarray(kj.doc_ids), np.asarray(kk.doc_ids))
    np.testing.assert_allclose(
        np.asarray(kj.scores), np.asarray(kk.scores), rtol=1e-5, atol=1e-6
    )
    for field in ("n_survivors", "blocks_scored", "chunks", "rank_safe"):
        np.testing.assert_array_equal(
            np.asarray(getattr(kj.stats, field)),
            np.asarray(getattr(kk.stats, field)),
            err_msg=f"WorkStats.{field} diverged between kernel and jnp phase 2",
        )
    return kk


# --------------------------------------------------------------------------
# SAAT: fused scatter→top-k
# --------------------------------------------------------------------------


@pytest.mark.parametrize("rho", RHO_LADDER)
def test_fused_saat_parity_across_rho_ladder(bm25_index, bm25_queries, rho):
    qt, qw = bm25_queries
    _assert_fused_saat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, rho=_resolve_rho(bm25_index, rho),
    )


def test_fused_saat_ragged_batch_with_pad_terms(bm25_index, bm25_queries):
    """Rows with progressively more zero-weight pad terms ride one executable."""
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:8]), np.array(qw[:8])
    for i in range(qt.shape[0]):
        keep = max(1, qt.shape[1] - i)
        qw[i, keep:] = 0.0
        qt[i, keep:] = bm25_index.n_terms  # pad slot
    f = _assert_fused_saat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10, rho=2000
    )
    totals = np.asarray(f.total_postings)
    assert totals[-1] <= totals[0]  # shorter queries have fewer candidates


def test_fused_saat_duplicate_query_terms(bm25_index, bm25_queries):
    """Duplicate terms contribute per slot, identically to the unfused path."""
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:4]), np.array(qw[:4])
    qt[:, 1] = qt[:, 0]
    _assert_fused_saat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10, rho=1000
    )


def test_fused_saat_all_pad_query_row(bm25_index, bm25_queries):
    """An all-zero-weight row yields empty results without poisoning others."""
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:4]), np.array(qw[:4])
    qw[2] = 0.0
    qt[2] = bm25_index.n_terms
    f = _assert_fused_saat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10, rho=1000
    )
    assert int(np.asarray(f.total_postings)[2]) == 0


def test_fused_saat_k_exceeds_n_docs():
    """k past the corpus clamps and pads with -inf ranks, exactly as unfused."""
    rng = np.random.default_rng(5)
    n_docs, n_terms = 50, 30
    d = rng.integers(0, n_docs, 400)
    t = rng.integers(0, n_terms, 400)
    w = rng.gamma(2.0, 1.0, 400)
    idx = build_impact_index(d, t, w, n_docs, n_terms)
    qt = jnp.asarray(rng.integers(0, n_terms, (3, 4)).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (3, 4)).astype(np.float32))
    f = _assert_fused_saat_parity(idx, qt, qw, k=n_docs + 10, rho=exact_rho(idx))
    # padded ranks hold -inf, never fabricated scores
    assert bool(np.isneginf(np.asarray(f.scores)[:, n_docs:]).all())


def test_fused_saat_batch_of_one(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    _assert_fused_saat_parity(
        bm25_index, jnp.asarray(qt[:1]), jnp.asarray(qw[:1]), k=5, rho=300
    )


def test_fused_saat_exact_rho_matches_exhaustive(bm25_index, bm25_queries):
    """The fused path at a rank-safe rho is exact end to end."""
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt), jnp.asarray(qw)
    f = saat_search(
        bm25_index, qt, qw, k=10, rho=exact_rho(bm25_index),
        max_segs_per_term=max_segments_per_term(bm25_index), fused_topk=True,
    )
    ex = exhaustive_search(bm25_index, qt, qw, k=10)
    np.testing.assert_allclose(
        np.asarray(f.scores), np.asarray(ex.scores), rtol=1e-3, atol=1e-3
    )


# --------------------------------------------------------------------------
# DAAT: kernel-backed phase 2
# --------------------------------------------------------------------------


@pytest.mark.parametrize("exact", [True, False])
def test_daat_kernels_match_jnp(bm25_index, bm25_queries, exact):
    qt, qw = bm25_queries
    _assert_daat_kernel_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=2, exact=exact,
    )


def test_daat_kernels_ragged_batch(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:8]), np.array(qw[:8])
    for i in range(qt.shape[0]):
        keep = max(1, qt.shape[1] - i)
        qw[i, keep:] = 0.0
        qt[i, keep:] = bm25_index.n_terms
    _assert_daat_kernel_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=1, exact=True,
    )


def test_daat_kernels_duplicate_and_zero_weight_terms(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:4]), np.array(qw[:4])
    qt[:, 1] = qt[:, 0]  # duplicate the heaviest term
    qw[:, 2] = 0.0  # and kill one real term
    _assert_daat_kernel_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=2, exact=True,
    )


def test_daat_kernels_k_exceeds_n_docs():
    rng = np.random.default_rng(5)
    n_docs, n_terms = 50, 30
    d = rng.integers(0, n_docs, 400)
    t = rng.integers(0, n_terms, 400)
    w = rng.gamma(2.0, 1.0, 400)
    idx = build_impact_index(d, t, w, n_docs, n_terms)
    qt = jnp.asarray(rng.integers(0, n_terms, (3, 4)).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (3, 4)).astype(np.float32))
    b = _assert_daat_kernel_parity(
        idx, qt, qw, k=n_docs + 10, est_blocks=idx.n_blocks, block_budget=1, exact=True,
    )
    assert bool(np.isneginf(np.asarray(b.scores)[:, n_docs:]).all())


def test_daat_kernels_max_chunks_cap(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    b = _assert_daat_kernel_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=1, block_budget=1, exact=True, max_chunks=1,
    )
    assert int(np.asarray(b.chunks).max()) <= 1


def test_daat_kernels_batch_of_one(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    _assert_daat_kernel_parity(
        bm25_index, jnp.asarray(qt[:1]), jnp.asarray(qw[:1]),
        k=5, est_blocks=1, block_budget=1, exact=True,
    )


# --------------------------------------------------------------------------
# serving integration: the flags must be end-to-end invisible in results
# --------------------------------------------------------------------------


def test_server_fused_topk_matches_exhaustive(bm25_index, bm25_queries):
    from repro.serving import AnytimeServer, ServingConfig, run_query_stream

    qt, qw = bm25_queries
    srv = AnytimeServer(
        bm25_index,
        ServingConfig(k=10, rho_ladder=(10**9,), batch_size=8, fused_topk=True),
    )
    scores, ids = run_query_stream(srv, qt, qw)
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(scores, np.asarray(ex.scores), rtol=1e-4, atol=1e-4)


def test_server_daat_kernels_matches_exhaustive(bm25_index, bm25_queries):
    from repro.serving import AnytimeServer, ServingConfig, run_query_stream

    qt, qw = bm25_queries
    srv = AnytimeServer(
        bm25_index,
        ServingConfig(
            k=10, batch_size=8, engine="daat",
            daat_est_blocks=2, daat_block_budget=2, daat_use_kernels=True,
        ),
    )
    scores, ids = run_query_stream(srv, qt, qw)
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(scores, np.asarray(ex.scores), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_shards", [1, 2])
def test_sharded_fused_topk_matches_exhaustive(
    tiny_corpus, bm25_collection, bm25_index, bm25_queries, n_shards
):
    """Per-shard fused scatter→top-k + id globalization + k-merge == oracle."""
    import jax

    from repro.serving import make_sharded_serve_step, shard_corpus, stack_indexes

    enc = bm25_collection
    qt, qw = bm25_queries
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms, n_shards
    )
    stacked = stack_indexes(shards)
    serve, _, _ = make_sharded_serve_step(
        mesh,
        k=10,
        rho_per_shard=max(s.n_postings for s in shards),
        max_segs_per_term=max(int(s.max_segs) for s in shards),
        docs_per_shard=dps,
        fused_topk=True,
    )
    with mesh:
        ss, si = serve(stacked, jnp.asarray(qt), jnp.asarray(qw))
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)
    assert (np.asarray(si) == np.asarray(ex.doc_ids)).mean() > 0.95  # ties may permute


@pytest.mark.parametrize("n_shards", [1, 2])
def test_sharded_daat_kernels_matches_exhaustive(
    tiny_corpus, bm25_collection, bm25_index, bm25_queries, n_shards
):
    """Per-shard kernel-backed DAAT phase 2 under shard_map == oracle."""
    import jax

    from repro.serving import make_sharded_serve_step, shard_corpus, stack_indexes

    enc = bm25_collection
    qt, qw = bm25_queries
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards, dps = shard_corpus(
        enc.doc_idx, enc.term_idx, enc.weights, tiny_corpus.n_docs, enc.n_terms, n_shards
    )
    stacked = stack_indexes(shards)
    serve, _, _ = make_sharded_serve_step(
        mesh,
        k=10,
        rho_per_shard=0,  # unused by the daat engine
        max_segs_per_term=0,
        docs_per_shard=dps,
        engine="daat",
        daat_est_blocks=2,
        daat_block_budget=2,
        max_bm_per_term=stacked.max_bm,
        daat_use_kernels=True,
    )
    with mesh:
        ss, si = serve(stacked, jnp.asarray(qt), jnp.asarray(qw))
    ex = exhaustive_search(bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)
    assert (np.asarray(si) == np.asarray(ex.doc_ids)).mean() > 0.8
