"""End-to-end behaviour: the paper's findings reproduced on a tiny corpus,
plus the trainable sparse-encoder loop and the wacky-weights analyzers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_impact_index, exhaustive_search, pad_queries
from repro.core.wacky import (
    accumulator_overflow,
    blockmax_tightness,
    skip_opportunity,
    term_statistics,
    weight_distribution_stats,
)
from repro.data.synthetic import CorpusConfig, generate_corpus, mismatch_rate
from repro.metrics.ir_metrics import mrr_at_k
from repro.models.treatments import MODEL_NAMES, apply_treatment


@pytest.fixture(scope="module")
def corpus():
    return generate_corpus(CorpusConfig(n_docs=1500, n_queries=80, n_concepts=200, seed=7))


@pytest.fixture(scope="module")
def encoded(corpus):
    return {m: apply_treatment(corpus, m) for m in ("bm25", "bm25-t5", "spladev2")}


def _search_mrr(corpus, enc, k=10):
    idx = build_impact_index(enc.doc_idx, enc.term_idx, enc.weights, corpus.n_docs, enc.n_terms)
    max_q = max(len(t) for t in enc.query_terms)
    qt, qw = pad_queries(enc.query_terms, enc.query_weights, max_q, enc.n_terms)
    res = exhaustive_search(idx, jnp.asarray(qt), jnp.asarray(qw), k=k)
    return mrr_at_k(np.asarray(res.doc_ids), corpus.qrels, k), idx, (qt, qw)


def test_vocabulary_mismatch_exists(corpus):
    """The corpus must exhibit the mismatch that expansion models fix."""
    assert mismatch_rate(corpus) > 0.15


def test_effectiveness_ordering(corpus, encoded):
    """Expansion + learned weights beat BM25 (paper Table 1 ordering)."""
    mrr = {m: _search_mrr(corpus, e)[0] for m, e in encoded.items()}
    assert mrr["bm25-t5"] > mrr["bm25"], mrr
    assert mrr["spladev2"] > mrr["bm25"] + 0.05, mrr


def test_wacky_weights_flatter_for_learned(encoded):
    s_bm25 = weight_distribution_stats(encoded["bm25"].weights)
    s_spl = weight_distribution_stats(encoded["spladev2"].weights)
    assert s_spl["cv"] < s_bm25["cv"]  # flatter distribution


def test_skip_opportunity_collapses_for_wacky(corpus, encoded):
    """The paper's central mechanism: learned weights kill DAAT skipping."""
    out = {}
    for m in ("bm25", "spladev2"):
        _, idx, (qt, qw) = _search_mrr(corpus, encoded[m])
        from repro.core.daat import max_blocks_per_term

        out[m] = skip_opportunity(
            idx, jnp.asarray(qt), jnp.asarray(qw), k=10,
            max_bm_per_term=max_blocks_per_term(idx),
        )["skippable_fraction_mean"]
    assert out["spladev2"] < out["bm25"], out


def test_blockmax_coverage_higher_for_wacky(corpus, encoded):
    """Wacky terms appear in (almost) EVERY doc block: a query term then
    contributes to every block's upper bound, which is what makes the bounds
    loose relative to the threshold. (Raw per-cell tightness is only
    meaningful at high coverage — on sparse BM25 terms a block max trivially
    equals the term max, so coverage is the discriminative statistic.)"""
    _, idx_b, _ = _search_mrr(corpus, encoded["bm25"])
    _, idx_s, _ = _search_mrr(corpus, encoded["spladev2"])
    cov_b = blockmax_tightness(idx_b)["cells_per_term_mean"] / idx_b.n_blocks
    cov_s = blockmax_tightness(idx_s)["cells_per_term_mean"] / idx_s.n_blocks
    assert cov_s > 2 * cov_b, (cov_s, cov_b)


def test_accumulator_overflow_for_learned_weights(corpus, encoded):
    """The 16-bit JASS accumulator overflow appears for learned models."""
    _, idx_s, _ = _search_mrr(corpus, encoded["spladev2"])
    rep = accumulator_overflow(idx_s, query_weight_max=64.0)
    assert rep["overflows"]


def test_term_statistics_expansion_visible(corpus, encoded):
    ts_b = term_statistics(
        encoded["bm25"].doc_idx, encoded["bm25"].term_idx, encoded["bm25"].weights,
        corpus.n_docs, encoded["bm25"].query_terms, encoded["bm25"].query_weights,
    )
    ts_s = term_statistics(
        encoded["spladev2"].doc_idx, encoded["spladev2"].term_idx, encoded["spladev2"].weights,
        corpus.n_docs, encoded["spladev2"].query_terms, encoded["spladev2"].query_weights,
    )
    assert ts_s.doc_unique_terms > ts_b.doc_unique_terms
    assert ts_s.query_unique_terms > ts_b.query_unique_terms
    assert ts_s.doc_total_terms > 5 * ts_b.doc_total_terms  # pseudo-doc mass


def test_all_treatments_encode(corpus):
    for m in MODEL_NAMES:
        enc = apply_treatment(corpus, m)
        assert len(enc.doc_idx) > 0 and (enc.weights > 0).all()


# ------------------------------------------------------- trainable encoder


def test_sparse_encoder_learns_ranking():
    """A few steps of the SPLADE-style encoder beat the untrained encoder."""
    from repro.data.pipeline import TripleSampler
    from repro.models.sparse_encoder import (
        SparseEncoderConfig,
        encode,
        encoder_backbone,
        encoder_loss,
        init_encoder_params,
        score,
    )
    from repro.train import AdamWConfig, init_train_state, make_train_step, train_loop

    corpus = generate_corpus(CorpusConfig(n_docs=300, n_queries=60, n_concepts=40, seed=1))
    cfg = SparseEncoderConfig(
        backbone=encoder_backbone(d_model=64, n_layers=2, vocab=corpus.config.n_surface_terms),
        flops_weight=1e-5,
        query_flops_weight=1e-5,
    )
    params = init_encoder_params(jax.random.PRNGKey(0), cfg)
    sampler = TripleSampler(corpus, q_len=8, d_len=32)
    step = make_train_step(
        lambda p, b: encoder_loss(p, b, cfg), AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40)
    )
    batches = [next(sampler.batches(16)) for _ in range(40)]
    state, hist = train_loop(step, init_train_state(params), batches)
    assert hist[-1]["pair_acc"] > max(hist[0]["pair_acc"], 0.6)
    assert hist[-1]["rank_loss"] < hist[0]["rank_loss"]


def test_sparse_encoder_flops_reg_sparsifies():
    """Stronger FLOPS regularization -> sparser document reps."""
    from repro.data.pipeline import TripleSampler
    from repro.models.sparse_encoder import (
        SparseEncoderConfig,
        encoder_backbone,
        encoder_loss,
        init_encoder_params,
    )
    from repro.train import AdamWConfig, init_train_state, make_train_step, train_loop

    corpus = generate_corpus(CorpusConfig(n_docs=200, n_queries=40, n_concepts=30, seed=2))
    sampler = TripleSampler(corpus, q_len=8, d_len=32)
    batches = [next(sampler.batches(8)) for _ in range(25)]
    nnz = {}
    for w in (1e-6, 3e-2):
        cfg = SparseEncoderConfig(
            backbone=encoder_backbone(d_model=48, n_layers=1, vocab=corpus.config.n_surface_terms),
            flops_weight=w,
            query_flops_weight=w,
        )
        params = init_encoder_params(jax.random.PRNGKey(3), cfg)
        step = make_train_step(
            lambda p, b, _c=cfg: encoder_loss(p, b, _c),
            AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=25),
        )
        state, hist = train_loop(step, init_train_state(params), batches)
        nnz[w] = hist[-1]["doc_nnz"]
    assert nnz[3e-2] < nnz[1e-6], nnz


def test_unicoil_head_no_expansion():
    """uniCOIL reps activate only input-token dims."""
    from repro.models.sparse_encoder import (
        SparseEncoderConfig,
        encode,
        encoder_backbone,
        init_encoder_params,
    )

    cfg = SparseEncoderConfig(
        backbone=encoder_backbone(d_model=32, n_layers=1, vocab=256), head="unicoil"
    )
    params = init_encoder_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray([[5, 9, 11, 0]], jnp.int32)
    mask = jnp.asarray([[True, True, True, False]])
    rep = encode(params, toks, mask, cfg)
    active = set(np.nonzero(np.asarray(rep[0]))[0].tolist())
    assert active <= {5, 9, 11}
