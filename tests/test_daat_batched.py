"""Adversarial parity suite for the natively batched Block-Max DAAT engine.

``daat_search_batched`` must be indistinguishable from the ``daat_search_vmap``
oracle — BIT-identical doc ids and per-query ``WorkStats`` — across the inputs
most likely to break a batched port of data-dependent threshold machinery:
ragged batches, duplicate query terms, zero-weight terms, ``k > n_docs``, and
both exact/approximate modes. Exhaustive-oracle comparisons are marked
``slow`` so the x64 CI parity entry stays fast.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_impact_index,
    daat_search_batched,
    daat_search_vmap,
    exhaustive_search,
)
from repro.core.daat import block_upper_bounds, daat_plan, max_blocks_per_term, query_vectors
from repro.core.impact_index import query_vector


def _assert_daat_parity(index, qt, qw, **kw):
    """Batched vs vmap oracle: bit-identical ids + per-query WorkStats."""
    kw.setdefault("max_bm_per_term", max_blocks_per_term(index))
    b = daat_search_batched(index, qt, qw, **kw)
    v = daat_search_vmap(index, qt, qw, **kw)
    np.testing.assert_array_equal(np.asarray(b.doc_ids), np.asarray(v.doc_ids))
    np.testing.assert_allclose(np.asarray(b.scores), np.asarray(v.scores), rtol=1e-5, atol=1e-6)
    for field in ("n_survivors", "blocks_scored", "chunks", "rank_safe"):
        np.testing.assert_array_equal(
            np.asarray(getattr(b.stats, field)),
            np.asarray(getattr(v.stats, field)),
            err_msg=f"WorkStats.{field} diverged",
        )
    return b


@pytest.mark.parametrize("exact", [True, False])
def test_batched_matches_vmap(bm25_index, bm25_queries, exact):
    qt, qw = bm25_queries
    _assert_daat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=2, exact=exact,
    )


@pytest.mark.parametrize("exact", [True, False])
def test_batched_ragged_batch_with_pad_terms(bm25_index, bm25_queries, exact):
    """Rows with progressively more zero-weight pad terms ride one executable."""
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:8]), np.array(qw[:8])
    for i in range(qt.shape[0]):
        keep = max(1, qt.shape[1] - i)
        qw[i, keep:] = 0.0
        qt[i, keep:] = bm25_index.n_terms  # pad slot
    _assert_daat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=1, exact=exact,
    )


def test_batched_duplicate_query_terms(bm25_index, bm25_queries):
    """Duplicate terms must sum in the query vector AND the block bounds."""
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:4]), np.array(qw[:4])
    qt[:, 1] = qt[:, 0]  # duplicate the heaviest term in every row
    b = _assert_daat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=2, exact=True,
    )
    assert bool(np.asarray(b.rank_safe).all())


def test_batched_zero_weight_terms(bm25_index, bm25_queries):
    """Zero-weight terms contribute nothing (same results with them dropped)."""
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:4]), np.array(qw[:4])
    qw[:, 1] = 0.0
    b = _assert_daat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=2, exact=True,
    )
    dropped = np.array(qt)
    dropped[:, 1] = bm25_index.n_terms  # pad slot: term absent entirely
    b2 = daat_search_batched(
        bm25_index, jnp.asarray(dropped), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=2,
        max_bm_per_term=max_blocks_per_term(bm25_index), exact=True,
    )
    np.testing.assert_array_equal(np.asarray(b.doc_ids), np.asarray(b2.doc_ids))
    np.testing.assert_allclose(np.asarray(b.scores), np.asarray(b2.scores), rtol=1e-6)


def test_batched_all_pad_query_row(bm25_index, bm25_queries):
    """An all-zero-weight row must stay masked, not poison its neighbors."""
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:4]), np.array(qw[:4])
    qw[2] = 0.0
    qt[2] = bm25_index.n_terms
    b = _assert_daat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=2, block_budget=2, exact=True,
    )
    assert int(np.asarray(b.n_survivors)[2]) == 0


@pytest.mark.parametrize("exact", [True, False])
def test_batched_k_exceeds_n_docs(exact):
    """k past the corpus size pads ranks with -inf identically on both paths."""
    rng = np.random.default_rng(5)
    n_docs, n_terms = 50, 30
    d = rng.integers(0, n_docs, 400)
    t = rng.integers(0, n_terms, 400)
    w = rng.gamma(2.0, 1.0, 400)
    idx = build_impact_index(d, t, w, n_docs, n_terms)
    qt = jnp.asarray(rng.integers(0, n_terms, (3, 4)).astype(np.int32))
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (3, 4)).astype(np.float32))
    k = n_docs + 10
    b = _assert_daat_parity(
        idx, qt, qw, k=k, est_blocks=idx.n_blocks, block_budget=1, exact=exact,
    )
    assert b.scores.shape == (3, k)
    # ranks past the corpus hold -inf (padded docs), never fabricated scores
    assert bool(np.isneginf(np.asarray(b.scores)[:, n_docs:]).all())


def test_k_past_phase1_pool_rejected(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    with pytest.raises(ValueError, match="est_blocks"):
        daat_search_batched(
            bm25_index, jnp.asarray(qt[:2]), jnp.asarray(qw[:2]),
            k=10_000, est_blocks=1, block_budget=1,
            max_bm_per_term=max_blocks_per_term(bm25_index),
        )


def test_daat_search_batched_rejects_unbatched_input(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    with pytest.raises(ValueError, match="B, Lq"):
        daat_search_batched(
            bm25_index, jnp.asarray(qt[0]), jnp.asarray(qw[0]),
            k=5, est_blocks=2, block_budget=2,
            max_bm_per_term=max_blocks_per_term(bm25_index),
        )


def test_batched_batch_of_one(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    _assert_daat_parity(
        bm25_index, jnp.asarray(qt[:1]), jnp.asarray(qw[:1]),
        k=5, est_blocks=1, block_budget=1, exact=True,
    )


def test_batched_max_chunks_cap(bm25_index, bm25_queries):
    """A tight chunk cap must stop both engines at the same (unsafe) state."""
    qt, qw = bm25_queries
    b = _assert_daat_parity(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw),
        k=10, est_blocks=1, block_budget=1, exact=True, max_chunks=1,
    )
    assert int(np.asarray(b.chunks).max()) <= 1


def test_daat_plan_matches_single_query_plans(bm25_index, bm25_queries):
    """daat_plan on [B, Lq] == stacking B single-query phase-0 passes."""
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt[:5]), jnp.asarray(qw[:5])
    mb = max_blocks_per_term(bm25_index)
    plan = daat_plan(bm25_index, qt, qw, mb)
    for i in range(qt.shape[0]):
        ub = block_upper_bounds(bm25_index, qt[i], qw[i], mb)
        qv = query_vector(bm25_index, qt[i], qw[i])
        np.testing.assert_allclose(np.asarray(plan.ub[i]), np.asarray(ub), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(plan.qvec[i]), np.asarray(qv), rtol=1e-6)


def test_query_vectors_batched_matches_single(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt[:6]), jnp.asarray(qw[:6])
    batched = query_vectors(bm25_index, qt, qw)
    for i in range(qt.shape[0]):
        np.testing.assert_allclose(
            np.asarray(batched[i]), np.asarray(query_vector(bm25_index, qt[i], qw[i]))
        )


def test_max_blocks_cached_without_device_sync(bm25_index):
    assert bm25_index.max_bm > 0
    assert max_blocks_per_term(bm25_index) == bm25_index.max_bm
    assert bm25_index.max_bm == int(np.asarray(bm25_index.term_bm_count).max())


@pytest.mark.slow
def test_batched_exact_equals_exhaustive(bm25_index, bm25_queries):
    """exact=True from the batched engine == the exhaustive rank-safe oracle."""
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt), jnp.asarray(qw)
    ex = exhaustive_search(bm25_index, qt, qw, k=10)
    b = daat_search_batched(
        bm25_index, qt, qw, k=10, est_blocks=2, block_budget=2,
        max_bm_per_term=max_blocks_per_term(bm25_index), exact=True,
    )
    assert bool(np.asarray(b.rank_safe).all())
    np.testing.assert_allclose(np.asarray(b.scores), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)
