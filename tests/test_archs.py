"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
config and runs one forward/train step on CPU (shape + finiteness checks),
plus consistency tests for the execution-knob variants (chunked attention,
vocab-chunked loss, prefill/decode caches)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.data import pipeline
from repro.train import AdamWConfig, init_train_state, make_train_step

LM_ARCHS = [a for a, s in ARCHS.items() if s.family == "lm"]
GNN_ARCHS = [a for a, s in ARCHS.items() if s.family == "gnn"]
RECSYS_ARCHS = [a for a, s in ARCHS.items() if s.family == "recsys"]


def _finite(tree) -> bool:
    return all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(tree) if jnp.issubdtype(l.dtype, jnp.floating))


def test_registry_complete():
    assert len(ARCHS) == 10
    assert sum(len(s.cells) for s in ARCHS.values()) == 40  # the assigned grid
    skips = [(a, c.name) for a, s in ARCHS.items() for c in s.cells.values() if c.skip]
    assert len(skips) == 4  # long_500k on the four pure full-attention archs
    assert all(n == "long_500k" for _, n in skips)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.archs.transformer import init_lm_params, lm_loss

    cfg = get_arch(arch).smoke_config()
    params = init_lm_params(jax.random.PRNGKey(0), cfg)
    step = make_train_step(
        lambda p, b: lm_loss(p, b["tokens"], b["labels"], cfg), AdamWConfig(warmup_steps=1)
    )
    state = init_train_state(params)
    batch = next(pipeline.lm_token_batches(cfg.vocab, 4, 32))
    state2, metrics = jax.jit(step)(state, batch)
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert _finite(state2.params)
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode(arch):
    from repro.archs.transformer import init_lm_params, lm_decode_step, lm_logits, lm_prefill

    cfg = get_arch(arch).smoke_config()
    params = init_lm_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
    logits, cache = lm_prefill(params, toks, cfg, cache_seq_len=16)
    assert logits.shape == (2, cfg.vocab) and _finite(logits)
    d_logits, cache2 = lm_decode_step(params, cache, toks[:, :1], jnp.array([12, 12]), cfg)
    assert d_logits.shape == (2, cfg.vocab) and _finite(d_logits)
    # decode at position 12 must equal the full causal forward on 13 tokens
    if cfg.moe is None:  # MoE capacity drops differ between shapes
        full = lm_logits(params, jnp.concatenate([toks, toks[:, :1]], 1), cfg)
        np.testing.assert_allclose(
            np.asarray(d_logits), np.asarray(full[:, -1, :]), rtol=2e-2, atol=2e-3
        )


def test_lm_vocab_chunked_loss_matches_dense():
    from repro.archs.transformer import init_lm_params, lm_loss

    spec = get_arch("gemma3-1b")
    cfg = spec.smoke_config()
    cfg_chunk = dataclasses.replace(cfg, vocab_chunk=8)
    params = init_lm_params(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 32), 0, cfg.vocab)
    labels = jnp.roll(toks, -1, 1)
    l1, _ = lm_loss(params, toks, labels, cfg)
    l2, _ = lm_loss(params, toks, labels, cfg_chunk)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_lm_chunked_attention_matches_dense():
    from repro.archs.transformer import init_lm_params, lm_logits

    cfg = get_arch("yi-34b").smoke_config()
    cfg_chunk = dataclasses.replace(cfg, attn_chunk=8)
    params = init_lm_params(jax.random.PRNGKey(5), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 32), 0, cfg.vocab)
    a = lm_logits(params, toks, cfg)
    b = lm_logits(params, toks, cfg_chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_gemma3_window_pattern():
    cfg = get_arch("gemma3-1b").config_for("train_4k")
    windows = [cfg.layer_window(l) for l in range(cfg.n_layers)]
    assert windows.count(0) == 4  # 4 global layers in 26 (5:1, 26 = 4*6+2)
    assert all(w in (0, 1024) for w in windows)
    assert cfg.cache_len(0, 524288) == 1024  # ring buffer for local layers
    assert cfg.cache_len(5, 524288) == 524288  # full cache for global layers


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    from repro.archs.gnn import gnn_loss, init_gnn_params

    cfg = get_arch(arch).smoke_config()
    params = init_gnn_params(jax.random.PRNGKey(0), cfg)
    step = make_train_step(lambda p, b: gnn_loss(p, b, cfg), AdamWConfig(warmup_steps=1))
    state = init_train_state(params)
    batch = next(pipeline.gnn_batches(cfg, n_nodes=64, n_edges=256))
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"])) and _finite(state2.params)


def test_gnn_smoke_readout():
    import dataclasses as dc

    from repro.archs.gnn import gnn_loss, init_gnn_params

    cfg = dc.replace(get_arch("graphcast").smoke_config(), graph_readout=True)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg)
    batch = next(pipeline.gnn_batches(cfg, 64, 256, graph_readout_graphs=8))
    loss, _ = gnn_loss(params, batch, cfg)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train(arch):
    from repro.archs.recsys import loss as recsys_loss
    from repro.archs.recsys import init_params

    cfg = get_arch(arch).smoke_config()
    params = init_params(jax.random.PRNGKey(0), cfg)
    step = make_train_step(lambda p, b: recsys_loss(p, b, cfg), AdamWConfig(warmup_steps=1))
    state = init_train_state(params)
    batch = next(pipeline.recsys_batches(cfg, 16))
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"])) and _finite(state2.params)


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_retrieval(arch):
    from repro.archs.recsys import init_params, retrieve_topk, score_candidates

    cfg = get_arch(arch).smoke_config()
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = next(pipeline.recsys_batches(cfg, 1))
    batch.pop("label", None)
    batch["candidates"] = jnp.arange(512, dtype=jnp.int32)
    scores = score_candidates(params, batch, cfg)
    assert scores.shape == (512,) and _finite(scores)
    s, i = retrieve_topk(params, batch, cfg, k=16, num_tiles=4)
    # top-k of the scored candidates must match a full sort
    np.testing.assert_allclose(np.asarray(s), np.sort(np.asarray(scores))[::-1][:16], rtol=1e-5)


def test_moe_group_consistency():
    """Grouped dispatch is numerically identical across G at high capacity."""
    import dataclasses as dc

    from repro.archs.layers import MoEConfig, moe, moe_params

    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    base = MoEConfig(n_experts=4, top_k=2, d_expert_ff=16, capacity_factor=8.0)
    p = moe_params(jax.random.PRNGKey(0), 32, base, jnp.float32)
    outs = []
    for g in (1, 2, 8):
        y, _ = moe(p, x, dc.replace(base, n_groups=g))
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5, atol=1e-6)
