"""Core retrieval invariants: quantization, index, SAAT/DAAT/exhaustive."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantConfig,
    blockmax_search,
    build_impact_index,
    dequantize,
    exact_rho,
    exhaustive_search,
    quantization_error,
    quantize,
    saat_search,
)
from repro.core.daat import max_blocks_per_term
from repro.core.saat import max_segments_per_term
from repro.core.topk import merge_topk, tiled_topk, topk
from repro.metrics.ir_metrics import mrr_at_k, rank_overlap


# ---------------------------------------------------------------- quantization


def test_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    w = rng.gamma(2.0, 3.0, 10000)
    for bits in (4, 8, 12):
        err = quantization_error(w, QuantConfig(bits=bits))
        assert err["bound_ok"], err


def test_quantize_zero_reserved():
    q, _ = quantize(np.array([0.0, -1.0, 0.5, 2.0]), QuantConfig(bits=8))
    assert q[0] == 0 and q[1] == 0 and q[2] >= 1 and q[3] >= 1


def test_quantize_monotone():
    w = np.linspace(0.01, 10, 1000)
    q, _ = quantize(w, QuantConfig(bits=8))
    assert (np.diff(q) >= 0).all()


def test_log_scheme_roundtrip():
    w = np.exp(np.random.default_rng(1).normal(0, 2, 1000))
    cfg = QuantConfig(bits=8, scheme="log")
    q, scale = quantize(w, cfg)
    deq = dequantize(q, scale, cfg)
    # log-scheme relative error stays bounded
    rel = np.abs(deq - w) / w
    assert np.median(rel) < 0.2


# ---------------------------------------------------------------- index


def test_index_invariants(bm25_index, bm25_collection):
    idx = bm25_index
    # segments ordered by (term, impact desc)
    seg_term = np.asarray(idx.seg_term)
    seg_w = np.asarray(idx.seg_weight)
    same_term = seg_term[1:] == seg_term[:-1]
    assert (seg_w[1:][same_term] <= seg_w[:-1][same_term] + 1e-6).all()
    # CSR covers all postings
    assert int(np.asarray(idx.term_post_count).sum()) == len(bm25_collection.doc_idx)
    # doc-major nnz matches
    assert int(np.asarray(idx.doc_n_terms).sum()) == len(bm25_collection.doc_idx)
    # block-max >= every posting weight in that (term, block)
    assert float(np.asarray(idx.term_max_weight).max()) > 0


def test_index_size_accounting(bm25_index):
    assert bm25_index.posting_store_nbytes() < bm25_index.nbytes()


def test_build_empty_corpus_index_is_inert():
    """A shard whose COO range holds no postings must still build and serve.

    All engines see zeroed CSR counts plus padded (never zero-length) stores,
    so every search returns all-zero scores instead of crashing."""
    z = np.zeros(0)
    idx = build_impact_index(z, z, z, 4, 5)
    assert idx.n_postings >= 1  # padded posting store: no zero-length gathers
    assert idx.seg_term.shape[0] >= 1 and idx.bm_block.shape[0] >= 1
    assert int(np.asarray(idx.term_post_count).sum()) == 0
    assert idx.max_segs == 0 and idx.max_bm == 0
    qt = jnp.asarray([[0, 2]], jnp.int32)
    qw = jnp.ones((1, 2), jnp.float32)
    ex = exhaustive_search(idx, qt, qw, k=3)
    assert np.all(np.asarray(ex.scores) == 0.0)
    sa = saat_search(idx, qt, qw, k=3, rho=idx.n_postings, max_segs_per_term=1)
    assert np.all(np.asarray(sa.scores) == 0.0)


# ---------------------------------------------------------------- evaluation


def test_saat_exact_equals_exhaustive(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt), jnp.asarray(qw)
    k = 10
    ex = exhaustive_search(bm25_index, qt, qw, k=k)
    sa = saat_search(
        bm25_index, qt, qw, k=k, rho=exact_rho(bm25_index),
        max_segs_per_term=max_segments_per_term(bm25_index),
    )
    np.testing.assert_allclose(np.asarray(sa.scores), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)
    assert rank_overlap(np.asarray(sa.doc_ids), np.asarray(ex.doc_ids), k) > 0.99


def test_saat_scatter_impls_agree(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt[:8]), jnp.asarray(qw[:8])
    ms = max_segments_per_term(bm25_index)
    res = {}
    for impl in ("jnp", "sort", "pallas"):
        r = saat_search(bm25_index, qt, qw, k=10, rho=5000, max_segs_per_term=ms, scatter_impl=impl)
        res[impl] = np.asarray(r.scores)
    np.testing.assert_allclose(res["jnp"], res["sort"], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(res["jnp"], res["pallas"], rtol=1e-3, atol=1e-3)


def test_saat_monotone_in_rho(bm25_index, bm25_queries, tiny_corpus):
    """More postings budget -> effectiveness never degrades (on average)."""
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt), jnp.asarray(qw)
    ms = max_segments_per_term(bm25_index)
    mrrs = []
    for rho in (200, 2000, exact_rho(bm25_index)):
        r = saat_search(bm25_index, qt, qw, k=10, rho=rho, max_segs_per_term=ms)
        mrrs.append(mrr_at_k(np.asarray(r.doc_ids), tiny_corpus.qrels, 10))
    assert mrrs[0] <= mrrs[1] + 0.02 and mrrs[1] <= mrrs[2] + 0.02, mrrs


def test_saat_postings_budget_respected(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    r = saat_search(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10, rho=500,
        max_segs_per_term=max_segments_per_term(bm25_index),
    )
    assert int(np.asarray(r.postings_processed).max()) <= 500


def test_daat_rank_safe_equals_exhaustive(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt), jnp.asarray(qw)
    ex = exhaustive_search(bm25_index, qt, qw, k=10)
    da = blockmax_search(
        bm25_index, qt, qw, k=10, est_blocks=2, block_budget=2,
        max_bm_per_term=max_blocks_per_term(bm25_index), exact=True,
    )
    assert bool(np.asarray(da.rank_safe).all())
    np.testing.assert_allclose(np.asarray(da.scores), np.asarray(ex.scores), rtol=1e-4, atol=1e-4)


def test_daat_skipping_happens_on_bm25(bm25_index, bm25_queries):
    """BM25's skewed weights must leave some blocks skippable."""
    qt, qw = bm25_queries
    da = blockmax_search(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10, est_blocks=1, block_budget=1,
        max_bm_per_term=max_blocks_per_term(bm25_index), exact=True,
    )
    scored = np.asarray(da.blocks_scored)
    assert (scored < bm25_index.n_blocks).any()


# ---------------------------------------------------------------- top-k utils


def test_tiled_topk_matches_full():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=4096), jnp.float32)
    s1, i1 = topk(x, 50)
    s2, i2 = tiled_topk(x, 50, num_tiles=8)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(np.asarray(x)[np.asarray(i2)], np.asarray(s1))


def test_tiled_topk_k_equals_pool_size():
    """k == n must return the whole pool, exactly sorted, with a valid
    permutation of indices — including when the pool is ragged across tiles
    (k is clamped per tile, so every tile contributes all of its entries)."""
    rng = np.random.default_rng(3)
    for n, num_tiles in ((96, 8), (100, 8), (7, 3)):  # even, ragged, tiny
        x = jnp.asarray(rng.normal(size=n), jnp.float32)
        s, i = tiled_topk(x, n, num_tiles=num_tiles)
        assert s.shape == (n,) and i.shape == (n,)
        np.testing.assert_allclose(np.asarray(s), np.sort(np.asarray(x))[::-1])
        # indices are a permutation of the pool and consistent with scores
        assert sorted(np.asarray(i).tolist()) == list(range(n))
        np.testing.assert_allclose(np.asarray(x)[np.asarray(i)], np.asarray(s))
    # k beyond the pool clamps to n (mirrors topk())
    x = jnp.asarray(rng.normal(size=16), jnp.float32)
    s, i = tiled_topk(x, 50, num_tiles=4)
    assert s.shape == (16,)


def test_merge_topk():
    sa = jnp.asarray([9.0, 5.0, 1.0])
    ia = jnp.asarray([1, 2, 3], jnp.int32)
    sb = jnp.asarray([7.0, 6.0, 0.5])
    ib = jnp.asarray([4, 5, 6], jnp.int32)
    ms, mi = merge_topk(sa, ia, sb, ib, 4)
    np.testing.assert_allclose(np.asarray(ms), [9.0, 7.0, 6.0, 5.0])
    np.testing.assert_array_equal(np.asarray(mi), [1, 4, 5, 2])
