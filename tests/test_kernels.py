"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle.

The whole module carries the ``kernels`` marker so CI can run the
interpret-mode sweeps as a standalone matrix entry — a kernel regression
fails in an attributable job instead of somewhere inside the full tier-1 run.

Sweep shapes come from each package's ``CONTRACT.shape_grid`` (see
``src/repro/analysis/README.md``): the static checker traces exactly the
shapes these tests execute, so adding a ``ShapeCase`` grows both gates at
once and the lists can never drift apart.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_prune.ops import CONTRACT as BLOCK_PRUNE_CONTRACT
from repro.kernels.block_prune.ops import block_prune, block_prune_batched
from repro.kernels.block_prune.ref import block_prune_batched_ref, block_prune_ref
from repro.kernels.block_topk.ops import CONTRACT as BLOCK_TOPK_CONTRACT
from repro.kernels.block_topk.ops import block_topk, block_topk_batched
from repro.kernels.block_topk.ref import block_topk_batched_ref, block_topk_ref
from repro.kernels.impact_scatter.ops import CONTRACT as SCATTER_CONTRACT
from repro.kernels.impact_scatter.ops import impact_scatter, impact_scatter_batched
from repro.kernels.impact_scatter.ref import impact_scatter_batched_ref, impact_scatter_ref
from repro.kernels.impact_scatter_topk.ops import CONTRACT as SCATTER_TOPK_CONTRACT
from repro.kernels.impact_scatter_topk.ops import (
    impact_scatter_topk,
    impact_scatter_topk_batched,
)
from repro.kernels.impact_scatter_topk.ref import (
    impact_scatter_topk_batched_ref,
    impact_scatter_topk_ref,
)
from repro.kernels.sparse_score.ops import CONTRACT as SPARSE_SCORE_CONTRACT
from repro.kernels.sparse_score.ops import sparse_score, sparse_score_batched
from repro.kernels.sparse_score.ref import sparse_score_batched_ref, sparse_score_ref

pytestmark = pytest.mark.kernels


@pytest.mark.parametrize("n_postings", SCATTER_CONTRACT.sweep_values("n_postings", exclude=("batch",)))
@pytest.mark.parametrize("n_docs", SCATTER_CONTRACT.sweep_values("n_docs", exclude=("batch",)))
@pytest.mark.parametrize("sort_by_doc", [True, False])
def test_impact_scatter_sweep(n_postings, n_docs, sort_by_doc):
    rng = np.random.default_rng(n_postings + n_docs)
    docs = jnp.asarray(rng.integers(0, n_docs, n_postings), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, n_postings), jnp.float32)
    got = impact_scatter(docs, contribs, n_docs, block_d=256, tile_p=128, sort_by_doc=sort_by_doc, interpret=True)
    want = impact_scatter_ref(docs, contribs, n_docs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_impact_scatter_dtypes(dtype):
    rng = np.random.default_rng(0)
    docs = jnp.asarray(rng.integers(0, 300, 512), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, 512), dtype)
    got = impact_scatter(docs, contribs, 300, interpret=True)
    want = impact_scatter_ref(docs, contribs.astype(jnp.float32), 300)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


def test_impact_scatter_zero_contrib_padding():
    docs = jnp.zeros(256, jnp.int32)
    contribs = jnp.zeros(256, jnp.float32)
    got = impact_scatter(docs, contribs, 128, interpret=True)
    assert float(jnp.abs(got).max()) == 0.0


@pytest.mark.parametrize("batch", SCATTER_CONTRACT.sweep_values("batch"))
@pytest.mark.parametrize("n_postings", SCATTER_CONTRACT.sweep_values("n_postings", require=("batch",)))
@pytest.mark.parametrize("sort_by_doc", [True, False])
def test_impact_scatter_batched_sweep(batch, n_postings, sort_by_doc):
    (n_docs,) = SCATTER_CONTRACT.sweep_values("n_docs", require=("batch",))
    rng = np.random.default_rng(batch * 1000 + n_postings)
    docs = jnp.asarray(rng.integers(0, n_docs, (batch, n_postings)), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, (batch, n_postings)), jnp.float32)
    got = impact_scatter_batched(
        docs, contribs, n_docs, block_d=256, tile_p=128, sort_by_doc=sort_by_doc, interpret=True
    )
    want = impact_scatter_batched_ref(docs, contribs, n_docs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_impact_scatter_batched_matches_per_query_kernel():
    """Batched kernel rows == the single-query kernel run row by row."""
    rng = np.random.default_rng(7)
    B, P, D = 4, 512, 600
    docs = jnp.asarray(rng.integers(0, D, (B, P)), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, (B, P)), jnp.float32)
    got = impact_scatter_batched(docs, contribs, D, block_d=256, tile_p=128, interpret=True)
    for b in range(B):
        row = impact_scatter(docs[b], contribs[b], D, block_d=256, tile_p=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(row), rtol=1e-5, atol=1e-5)


def test_impact_scatter_batched_rows_independent():
    """A hot row must not leak into its neighbors' accumulators."""
    B, P, D = 3, 256, 512
    docs = jnp.zeros((B, P), jnp.int32)
    contribs = jnp.zeros((B, P), jnp.float32)
    contribs = contribs.at[1, :].set(1.0)
    got = impact_scatter_batched(docs, contribs, D, block_d=256, tile_p=128, interpret=True)
    assert float(jnp.abs(got[0]).max()) == 0.0 and float(jnp.abs(got[2]).max()) == 0.0
    assert float(got[1, 0]) == float(P)


# ---------------------------------------------------------------------------
# impact_scatter_topk: fused scatter → per-block top-k
# ---------------------------------------------------------------------------


def _fused_parity(docs, contribs, n_docs, k, *, n_live=None, block_d=256, tile_p=128):
    """Fused op (interpret) vs the dense scatter+mask+topk oracle."""
    n_live = n_docs if n_live is None else n_live
    if docs.ndim == 1:
        got = impact_scatter_topk(
            docs, contribs, n_docs, k, n_live=n_live,
            block_d=block_d, tile_p=tile_p, interpret=True,
        )
        want = impact_scatter_topk_ref(docs, contribs, n_docs, n_live, k)
    else:
        got = impact_scatter_topk_batched(
            docs, contribs, n_docs, k, n_live=n_live,
            block_d=block_d, tile_p=tile_p, interpret=True,
        )
        want = impact_scatter_topk_batched_ref(docs, contribs, n_docs, n_live, k)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-5, atol=1e-5)
    return got


@pytest.mark.parametrize("n_postings", SCATTER_TOPK_CONTRACT.sweep_values("n_postings", exclude=("batch",)))
@pytest.mark.parametrize("n_docs", SCATTER_TOPK_CONTRACT.sweep_values("n_docs", exclude=("batch",)))
@pytest.mark.parametrize("k", SCATTER_TOPK_CONTRACT.sweep_values("k", exclude=("batch",)))
def test_impact_scatter_topk_sweep(n_postings, n_docs, k):
    rng = np.random.default_rng(n_postings + n_docs + k)
    docs = jnp.asarray(rng.integers(0, n_docs, n_postings), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, n_postings), jnp.float32)
    _fused_parity(docs, contribs, n_docs, k)


@pytest.mark.parametrize("batch", SCATTER_TOPK_CONTRACT.sweep_values("batch"))
@pytest.mark.parametrize("sort_by_doc", [True, False])
def test_impact_scatter_topk_batched_sweep(batch, sort_by_doc):
    """Non-divisible n_docs/tile_p shapes, with and without skip ranges."""
    # 700 % 256 != 0, 1000 % 128 != 0 (the contract's ragged batched case)
    (n_docs,) = SCATTER_TOPK_CONTRACT.sweep_values("n_docs", require=("batch",))
    (n_postings,) = SCATTER_TOPK_CONTRACT.sweep_values("n_postings", require=("batch",))
    (k,) = SCATTER_TOPK_CONTRACT.sweep_values("k", require=("batch",))
    rng = np.random.default_rng(batch * 1000)
    docs = jnp.asarray(rng.integers(0, n_docs, (batch, n_postings)), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, (batch, n_postings)), jnp.float32)
    got = impact_scatter_topk_batched(
        docs, contribs, n_docs, k, block_d=256, tile_p=128,
        sort_by_doc=sort_by_doc, interpret=True,
    )
    want = impact_scatter_topk_batched_ref(docs, contribs, n_docs, n_docs, k)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-5, atol=1e-5)


def test_impact_scatter_topk_all_postings_one_doc():
    """Degenerate hot doc: every posting lands on doc 7 of one block."""
    P, D = 512, 640
    docs = jnp.full((P,), 7, jnp.int32)
    contribs = jnp.asarray(np.random.default_rng(0).gamma(2.0, 1.0, P), jnp.float32)
    s, i = _fused_parity(docs, contribs, D, 5)
    assert int(np.asarray(i)[0]) == 7
    np.testing.assert_allclose(float(np.asarray(s)[0]), float(contribs.sum()), rtol=1e-5)


def test_impact_scatter_topk_all_zero_contribs():
    """All-zero contributions: ties resolve to ascending doc ids, scores 0."""
    docs = jnp.asarray(np.random.default_rng(1).integers(0, 500, 256), jnp.int32)
    contribs = jnp.zeros((256,), jnp.float32)
    s, i = _fused_parity(docs, contribs, 500, 8)
    np.testing.assert_allclose(np.asarray(s), 0.0)
    np.testing.assert_array_equal(np.asarray(i), np.arange(8))


def test_impact_scatter_topk_k_exceeds_block_survivors():
    """k larger than a block's surviving (live) candidates: -inf fill ranks.

    n_live=40 leaves one partial block of live docs; k=64 must surface all 40
    live docs then ascending masked ids, bit-identical to the dense oracle.
    """
    rng = np.random.default_rng(2)
    docs = jnp.asarray(rng.integers(0, 40, 256), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, 256), jnp.float32)
    s, i = _fused_parity(docs, contribs, 512, 64, n_live=40)
    assert bool(np.isfinite(np.asarray(s)[:40]).all())
    assert bool(np.isneginf(np.asarray(s)[40:]).all())


def test_impact_scatter_topk_batch_of_one():
    rng = np.random.default_rng(3)
    docs = jnp.asarray(rng.integers(0, 300, (1, 384)), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, (1, 384)), jnp.float32)
    got = _fused_parity(docs, contribs, 300, 9)
    assert got[0].shape == (1, 9)


def test_impact_scatter_topk_batched_matches_per_query_kernel():
    """Batched kernel rows == the single-query fused kernel run row by row."""
    rng = np.random.default_rng(7)
    B, P, D = 4, 512, 600
    docs = jnp.asarray(rng.integers(0, D, (B, P)), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, (B, P)), jnp.float32)
    gs, gi = impact_scatter_topk_batched(
        docs, contribs, D, 11, block_d=256, tile_p=128, interpret=True
    )
    for b in range(B):
        rs, ri = impact_scatter_topk(
            docs[b], contribs[b], D, 11, block_d=256, tile_p=128, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(gi[b]), np.asarray(ri))
        np.testing.assert_allclose(np.asarray(gs[b]), np.asarray(rs), rtol=1e-5, atol=1e-5)


def test_impact_scatter_topk_matches_unfused_pallas_bitwise():
    """Same accumulation kernel -> fused scores are BIT-equal to unfused."""
    rng = np.random.default_rng(11)
    docs = jnp.asarray(rng.integers(0, 700, (2, 512)), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, (2, 512)), jnp.float32)
    acc = impact_scatter_batched(docs, contribs, 700, block_d=256, tile_p=128, interpret=True)
    ds, di = jax.lax.top_k(acc, 15)
    fs, fi = impact_scatter_topk_batched(
        docs, contribs, 700, 15, block_d=256, tile_p=128, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(di))
    np.testing.assert_array_equal(np.asarray(fs), np.asarray(ds))


def test_impact_scatter_topk_single_query_bitwise_with_duplicate_docs():
    """Fused and unfused single-query wrappers share ONE sort primitive
    (``sorted_posting_tiles``), so heavy doc-id duplication — where an
    unstable vs stable sort would permute equal-key payloads and reorder the
    f32 accumulation — still yields BIT-equal scores."""
    rng = np.random.default_rng(17)
    docs = jnp.asarray(rng.integers(0, 10, 512), jnp.int32)  # ~51 postings/doc
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, 512), jnp.float32)
    acc = impact_scatter(docs, contribs, 300, block_d=256, tile_p=128, interpret=True)
    ds, di = jax.lax.top_k(acc, 12)
    fs, fi = impact_scatter_topk(
        docs, contribs, 300, 12, block_d=256, tile_p=128, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(di))
    np.testing.assert_array_equal(np.asarray(fs), np.asarray(ds))


@pytest.mark.parametrize("n,k,tile", BLOCK_TOPK_CONTRACT.sweep("n", "k", "tile", exclude=("batch",)))
def test_block_topk_sweep(n, k, tile):
    rng = np.random.default_rng(n + k)
    scores = jnp.asarray(rng.normal(size=n), jnp.float32)
    s, i = block_topk(scores, k, tile=tile, interpret=True)
    rs, ri = block_topk_ref(scores, min(k, n))
    np.testing.assert_allclose(np.asarray(s)[: min(k, n)], np.asarray(rs), rtol=1e-6)
    # ids must point at the same scores (ties may permute)
    np.testing.assert_allclose(
        np.asarray(scores)[np.asarray(i)[: min(k, n)]], np.asarray(rs), rtol=1e-6
    )


def test_block_topk_with_neg_inf():
    scores = jnp.asarray([1.0, -jnp.inf, 3.0, -jnp.inf, 2.0], jnp.float32)
    s, i = block_topk(scores, 3, tile=128, interpret=True)
    np.testing.assert_allclose(np.asarray(s), [3.0, 2.0, 1.0])


@pytest.mark.parametrize("lq,nb", BLOCK_PRUNE_CONTRACT.sweep("lq", "nb", exclude=("batch",)))
def test_block_prune_sweep(lq, nb):
    rng = np.random.default_rng(lq * nb)
    bm = jnp.asarray(rng.gamma(1.0, 1.0, (lq, nb)) * (rng.random((lq, nb)) > 0.3), jnp.float32)
    qw = jnp.asarray(rng.gamma(1.0, 1.0, lq), jnp.float32)
    theta = jnp.float32(np.quantile(np.asarray(bm).sum(0), 0.7))
    ub, mask = block_prune(bm, qw, theta, block_nb=256, interpret=True)
    rub, rmask = block_prune_ref(bm, qw, theta)
    np.testing.assert_allclose(np.asarray(ub), np.asarray(rub), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(rmask))


@pytest.mark.parametrize("batch,n,k,tile", BLOCK_TOPK_CONTRACT.sweep("batch", "n", "k", "tile"))
def test_block_topk_batched_sweep(batch, n, k, tile):
    """Non-divisible n/tile shapes; per-row finalists must match the oracle."""
    rng = np.random.default_rng(batch * 10 + n + k)
    scores = jnp.asarray(rng.normal(size=(batch, n)), jnp.float32)
    s, i = block_topk_batched(scores, k, tile=tile, interpret=True)
    rs, ri = block_topk_batched_ref(scores, min(k, n))
    ke = min(k, n)
    np.testing.assert_allclose(np.asarray(s)[:, :ke], np.asarray(rs), rtol=1e-6)
    np.testing.assert_allclose(  # ids must point at the same scores (ties may permute)
        np.take_along_axis(np.asarray(scores), np.asarray(i)[:, :ke], axis=-1),
        np.asarray(rs), rtol=1e-6,
    )


def test_block_topk_batched_matches_per_row_kernel():
    rng = np.random.default_rng(3)
    scores = jnp.asarray(rng.normal(size=(4, 600)), jnp.float32)
    s, i = block_topk_batched(scores, 9, tile=128, interpret=True)
    for b in range(4):
        rs, ri = block_topk(scores[b], 9, tile=128, interpret=True)
        np.testing.assert_allclose(np.asarray(s[b]), np.asarray(rs), rtol=1e-6)


def test_block_topk_batched_k_exceeds_n_pads():
    scores = jnp.asarray(np.random.default_rng(1).normal(size=(2, 40)), jnp.float32)
    s, i = block_topk_batched(scores, 50, tile=128, interpret=True)
    assert s.shape == (2, 50)
    assert bool(np.isneginf(np.asarray(s)[:, 40:]).all())


@pytest.mark.parametrize("batch,lq,nb", BLOCK_PRUNE_CONTRACT.sweep("batch", "lq", "nb"))
def test_block_prune_batched_sweep(batch, lq, nb):
    """Non-divisible block counts; each row pruned against its own theta."""
    rng = np.random.default_rng(batch * 100 + lq * nb)
    bm = jnp.asarray(
        rng.gamma(1.0, 1.0, (batch, lq, nb)) * (rng.random((batch, lq, nb)) > 0.3), jnp.float32
    )
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (batch, lq)), jnp.float32)
    theta = jnp.asarray(np.quantile(np.asarray(bm).sum(1), 0.7, axis=-1), jnp.float32)
    ub, mask = block_prune_batched(bm, qw, theta, block_nb=256, interpret=True)
    rub, rmask = block_prune_batched_ref(bm, qw, theta)
    np.testing.assert_allclose(np.asarray(ub), np.asarray(rub), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(rmask))


def test_block_prune_batched_matches_per_row_kernel():
    rng = np.random.default_rng(9)
    B, lq, nb = 3, 6, 130
    bm = jnp.asarray(rng.gamma(1.0, 1.0, (B, lq, nb)), jnp.float32)
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (B, lq)), jnp.float32)
    theta = jnp.asarray(rng.gamma(2.0, 2.0, B), jnp.float32)
    ub, mask = block_prune_batched(bm, qw, theta, block_nb=128, interpret=True)
    for b in range(B):
        rub, rmask = block_prune(bm[b], qw[b], theta[b], block_nb=128, interpret=True)
        np.testing.assert_allclose(np.asarray(ub[b]), np.asarray(rub), rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(mask[b]), np.asarray(rmask))


def test_block_prune_batched_degenerate_all_and_none_pruned():
    """theta below every ub keeps all nonempty blocks; theta above kills all."""
    rng = np.random.default_rng(4)
    B, lq, nb = 2, 4, 260  # non-divisible by the 128 tile
    bm = jnp.asarray(rng.gamma(1.0, 1.0, (B, lq, nb)) + 0.1, jnp.float32)
    qw = jnp.asarray(np.ones((B, lq)), jnp.float32)
    ub_ref, _ = block_prune_batched_ref(bm, qw, jnp.zeros((B,), jnp.float32))
    lo = jnp.full((B,), -1.0, jnp.float32)
    hi = jnp.asarray(np.asarray(ub_ref).max(-1) + 1.0, jnp.float32)
    _, mask_none = block_prune_batched(bm, qw, lo, block_nb=128, interpret=True)
    _, mask_all = block_prune_batched(bm, qw, hi, block_nb=128, interpret=True)
    assert bool(np.asarray(mask_none).all())  # none pruned: every block survives
    assert not bool(np.asarray(mask_all).any())  # all pruned: nothing survives
    # rows see only their own theta: mixing lo/hi prunes exactly one row
    mixed = jnp.asarray([float(lo[0]), float(hi[1])], jnp.float32)
    _, mask_mix = block_prune_batched(bm, qw, mixed, block_nb=128, interpret=True)
    assert bool(np.asarray(mask_mix)[0].all()) and not bool(np.asarray(mask_mix)[1].any())


def test_block_prune_batched_empty_blocks_never_survive():
    """ub == 0 blocks (no query term present) stay dead even with theta < 0."""
    bm = jnp.zeros((2, 3, 140), jnp.float32)
    qw = jnp.ones((2, 3), jnp.float32)
    theta = jnp.full((2,), -5.0, jnp.float32)
    _, mask = block_prune_batched(bm, qw, theta, block_nb=128, interpret=True)
    assert not bool(np.asarray(mask).any())


@pytest.mark.parametrize("n,tmax,lq", SPARSE_SCORE_CONTRACT.sweep("n", "tmax", "lq", exclude=("batch",)))
def test_sparse_score_sweep(n, tmax, lq):
    rng = np.random.default_rng(n + tmax + lq)
    V = 500
    dt = jnp.asarray(rng.integers(0, V, (n, tmax)), jnp.int32)
    dw = jnp.asarray(rng.gamma(1.0, 1.0, (n, tmax)), jnp.float32)
    qt = jnp.asarray(rng.choice(V, lq, replace=False), jnp.int32)
    qw = jnp.asarray(rng.gamma(1.0, 1.0, lq), jnp.float32)
    got = sparse_score(dt, dw, qt, qw, block_d=128, interpret=True)
    want = sparse_score_ref(dt, dw, qt, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_sparse_score_duplicate_query_terms():
    """Duplicate query terms must both contribute (sum semantics)."""
    dt = jnp.asarray([[3, 5]], jnp.int32)
    dw = jnp.asarray([[2.0, 1.0]], jnp.float32)
    qt = jnp.asarray([3, 3], jnp.int32)
    qw = jnp.asarray([1.0, 0.5], jnp.float32)
    got = sparse_score(dt, dw, qt, qw, interpret=True)
    want = sparse_score_ref(dt, dw, qt, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    np.testing.assert_allclose(np.asarray(got), [3.0])


@pytest.mark.parametrize("batch,n,tmax,lq", SPARSE_SCORE_CONTRACT.sweep("batch", "n", "tmax", "lq"))
def test_sparse_score_batched_sweep(batch, n, tmax, lq):
    """Each query scores its own doc rows; non-divisible doc counts pad."""
    rng = np.random.default_rng(batch + n + tmax + lq)
    V = 500
    dt = jnp.asarray(rng.integers(0, V, (batch, n, tmax)), jnp.int32)
    dw = jnp.asarray(rng.gamma(1.0, 1.0, (batch, n, tmax)), jnp.float32)
    qt = jnp.asarray(rng.integers(0, V, (batch, lq)), jnp.int32)
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (batch, lq)), jnp.float32)
    got = sparse_score_batched(dt, dw, qt, qw, block_d=128, interpret=True)
    want = sparse_score_batched_ref(dt, dw, qt, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_sparse_score_batched_matches_per_query_kernel():
    rng = np.random.default_rng(13)
    B, n, tmax, lq = 3, 200, 9, 5
    dt = jnp.asarray(rng.integers(0, 300, (B, n, tmax)), jnp.int32)
    dw = jnp.asarray(rng.gamma(1.0, 1.0, (B, n, tmax)), jnp.float32)
    qt = jnp.asarray(rng.integers(0, 300, (B, lq)), jnp.int32)
    qw = jnp.asarray(rng.gamma(1.0, 1.0, (B, lq)), jnp.float32)
    got = sparse_score_batched(dt, dw, qt, qw, block_d=64, interpret=True)
    for b in range(B):
        row = sparse_score(dt[b], dw[b], qt[b], qw[b], block_d=64, interpret=True)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(row), rtol=1e-5, atol=1e-5)


def test_sparse_score_batched_rows_independent():
    """Query b must never score with query c's weights."""
    dt = jnp.asarray(np.full((2, 64, 2), 3), jnp.int32)
    dw = jnp.asarray(np.ones((2, 64, 2)), jnp.float32)
    qt = jnp.asarray([[3, 4], [9, 9]], jnp.int32)  # row 1 matches nothing
    qw = jnp.asarray([[1.0, 1.0], [1.0, 1.0]], jnp.float32)
    got = np.asarray(sparse_score_batched(dt, dw, qt, qw, block_d=64, interpret=True))
    np.testing.assert_allclose(got[0], 2.0)  # two slots of term 3, weight 1 each
    np.testing.assert_allclose(got[1], 0.0)
