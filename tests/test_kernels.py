"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_prune.ops import block_prune
from repro.kernels.block_prune.ref import block_prune_ref
from repro.kernels.block_topk.ops import block_topk
from repro.kernels.block_topk.ref import block_topk_ref
from repro.kernels.impact_scatter.ops import impact_scatter, impact_scatter_batched
from repro.kernels.impact_scatter.ref import impact_scatter_batched_ref, impact_scatter_ref
from repro.kernels.sparse_score.ops import sparse_score
from repro.kernels.sparse_score.ref import sparse_score_ref


@pytest.mark.parametrize("n_postings", [128, 1000, 4096])
@pytest.mark.parametrize("n_docs", [512, 1000])
@pytest.mark.parametrize("sort_by_doc", [True, False])
def test_impact_scatter_sweep(n_postings, n_docs, sort_by_doc):
    rng = np.random.default_rng(n_postings + n_docs)
    docs = jnp.asarray(rng.integers(0, n_docs, n_postings), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, n_postings), jnp.float32)
    got = impact_scatter(docs, contribs, n_docs, block_d=256, tile_p=128, sort_by_doc=sort_by_doc, interpret=True)
    want = impact_scatter_ref(docs, contribs, n_docs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_impact_scatter_dtypes(dtype):
    rng = np.random.default_rng(0)
    docs = jnp.asarray(rng.integers(0, 300, 512), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, 512), dtype)
    got = impact_scatter(docs, contribs, 300, interpret=True)
    want = impact_scatter_ref(docs, contribs.astype(jnp.float32), 300)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


def test_impact_scatter_zero_contrib_padding():
    docs = jnp.zeros(256, jnp.int32)
    contribs = jnp.zeros(256, jnp.float32)
    got = impact_scatter(docs, contribs, 128, interpret=True)
    assert float(jnp.abs(got).max()) == 0.0


@pytest.mark.parametrize("batch", [1, 3, 8])
@pytest.mark.parametrize("n_postings", [128, 1000])
@pytest.mark.parametrize("sort_by_doc", [True, False])
def test_impact_scatter_batched_sweep(batch, n_postings, sort_by_doc):
    n_docs = 700
    rng = np.random.default_rng(batch * 1000 + n_postings)
    docs = jnp.asarray(rng.integers(0, n_docs, (batch, n_postings)), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, (batch, n_postings)), jnp.float32)
    got = impact_scatter_batched(
        docs, contribs, n_docs, block_d=256, tile_p=128, sort_by_doc=sort_by_doc, interpret=True
    )
    want = impact_scatter_batched_ref(docs, contribs, n_docs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_impact_scatter_batched_matches_per_query_kernel():
    """Batched kernel rows == the single-query kernel run row by row."""
    rng = np.random.default_rng(7)
    B, P, D = 4, 512, 600
    docs = jnp.asarray(rng.integers(0, D, (B, P)), jnp.int32)
    contribs = jnp.asarray(rng.gamma(2.0, 1.0, (B, P)), jnp.float32)
    got = impact_scatter_batched(docs, contribs, D, block_d=256, tile_p=128, interpret=True)
    for b in range(B):
        row = impact_scatter(docs[b], contribs[b], D, block_d=256, tile_p=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(row), rtol=1e-5, atol=1e-5)


def test_impact_scatter_batched_rows_independent():
    """A hot row must not leak into its neighbors' accumulators."""
    B, P, D = 3, 256, 512
    docs = jnp.zeros((B, P), jnp.int32)
    contribs = jnp.zeros((B, P), jnp.float32)
    contribs = contribs.at[1, :].set(1.0)
    got = impact_scatter_batched(docs, contribs, D, block_d=256, tile_p=128, interpret=True)
    assert float(jnp.abs(got[0]).max()) == 0.0 and float(jnp.abs(got[2]).max()) == 0.0
    assert float(got[1, 0]) == float(P)


@pytest.mark.parametrize("n,k,tile", [(1000, 10, 256), (8192, 100, 1024), (100, 100, 128), (5000, 7, 512)])
def test_block_topk_sweep(n, k, tile):
    rng = np.random.default_rng(n + k)
    scores = jnp.asarray(rng.normal(size=n), jnp.float32)
    s, i = block_topk(scores, k, tile=tile, interpret=True)
    rs, ri = block_topk_ref(scores, min(k, n))
    np.testing.assert_allclose(np.asarray(s)[: min(k, n)], np.asarray(rs), rtol=1e-6)
    # ids must point at the same scores (ties may permute)
    np.testing.assert_allclose(
        np.asarray(scores)[np.asarray(i)[: min(k, n)]], np.asarray(rs), rtol=1e-6
    )


def test_block_topk_with_neg_inf():
    scores = jnp.asarray([1.0, -jnp.inf, 3.0, -jnp.inf, 2.0], jnp.float32)
    s, i = block_topk(scores, 3, tile=128, interpret=True)
    np.testing.assert_allclose(np.asarray(s), [3.0, 2.0, 1.0])


@pytest.mark.parametrize("lq,nb", [(8, 100), (32, 2048), (5, 17)])
def test_block_prune_sweep(lq, nb):
    rng = np.random.default_rng(lq * nb)
    bm = jnp.asarray(rng.gamma(1.0, 1.0, (lq, nb)) * (rng.random((lq, nb)) > 0.3), jnp.float32)
    qw = jnp.asarray(rng.gamma(1.0, 1.0, lq), jnp.float32)
    theta = jnp.float32(np.quantile(np.asarray(bm).sum(0), 0.7))
    ub, mask = block_prune(bm, qw, theta, block_nb=256, interpret=True)
    rub, rmask = block_prune_ref(bm, qw, theta)
    np.testing.assert_allclose(np.asarray(ub), np.asarray(rub), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(rmask))


@pytest.mark.parametrize("n,tmax,lq", [(100, 16, 8), (512, 64, 32), (130, 7, 3)])
def test_sparse_score_sweep(n, tmax, lq):
    rng = np.random.default_rng(n + tmax + lq)
    V = 500
    dt = jnp.asarray(rng.integers(0, V, (n, tmax)), jnp.int32)
    dw = jnp.asarray(rng.gamma(1.0, 1.0, (n, tmax)), jnp.float32)
    qt = jnp.asarray(rng.choice(V, lq, replace=False), jnp.int32)
    qw = jnp.asarray(rng.gamma(1.0, 1.0, lq), jnp.float32)
    got = sparse_score(dt, dw, qt, qw, block_d=128, interpret=True)
    want = sparse_score_ref(dt, dw, qt, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_sparse_score_duplicate_query_terms():
    """Duplicate query terms must both contribute (sum semantics)."""
    dt = jnp.asarray([[3, 5]], jnp.int32)
    dw = jnp.asarray([[2.0, 1.0]], jnp.float32)
    qt = jnp.asarray([3, 3], jnp.int32)
    qw = jnp.asarray([1.0, 0.5], jnp.float32)
    got = sparse_score(dt, dw, qt, qw, interpret=True)
    want = sparse_score_ref(dt, dw, qt, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
    np.testing.assert_allclose(np.asarray(got), [3.0])
