"""Sharding rule-table unit tests (pure functions; no multi-device needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    Axes,
    FSDP_MIN_BYTES,
    LM_RULES,
    RECSYS_RULES,
    spec_for_path,
)

AXES = Axes(data=("data",))
MESH = {"data": 16, "model": 16}
MULTI = Axes(data=("pod", "data"))
MESH_MULTI = {"pod": 2, "data": 16, "model": 16}
BIG = FSDP_MIN_BYTES + 1


def test_lm_column_parallel():
    s = spec_for_path(".blocks.0.attn.wq", (7168, 7168), LM_RULES, AXES, MESH, BIG)
    assert s == P("data", "model")


def test_lm_row_parallel():
    s = spec_for_path(".blocks.0.attn.wo", (7168, 7168), LM_RULES, AXES, MESH, BIG)
    assert s == P("model", "data")


def test_lm_small_leaf_drops_fsdp():
    s = spec_for_path(".blocks.0.attn.wq", (1152, 1024), LM_RULES, AXES, MESH, nbytes=1024)
    assert s == P(None, "model")


def test_lm_stacked_leading_axes_unsharded():
    s = spec_for_path(".blocks.0.mlp.w_up", (4, 6, 1152, 6912), LM_RULES, AXES, MESH, BIG)
    assert s == P(None, None, "data", "model")


def test_lm_vocab_sharded_embed():
    s = spec_for_path(".embed", (256000, 3072), LM_RULES, AXES, MESH, BIG)
    assert s == P("model", None)


def test_moe_ep_when_divisible():
    s = spec_for_path(".blocks.0.moe.w_gate", (64, 2048, 1408), LM_RULES, AXES, MESH, BIG)
    assert s == P("model", "data", None)


def test_moe_fallback_when_not_divisible():
    s = spec_for_path(".blocks.0.moe.w_gate", (40, 1536, 512), LM_RULES, AXES, MESH, BIG)
    assert s == P(None, "data", "model")


def _replicated(spec: P) -> bool:
    return all(e is None for e in spec)


def test_norms_replicated():
    s = spec_for_path(".blocks.0.ln_attn.scale", (7168,), LM_RULES, AXES, MESH, BIG)
    assert _replicated(s)


def test_recsys_table_all_axes():
    s = spec_for_path(".table", (41_943_040, 16), RECSYS_RULES, AXES, MESH, BIG)
    assert s == P(("data", "model"), None)


def test_recsys_table_fallback_model_only():
    # 1040 rows: divisible by 16 (model) but not 256 (all)
    s = spec_for_path(".table", (1040, 16), RECSYS_RULES, AXES, MESH, BIG)
    assert s == P("model", None)


def test_recsys_tiny_table_replicated():
    s = spec_for_path(".table", (100, 16), RECSYS_RULES, AXES, MESH, BIG)
    assert _replicated(s)


def test_multipod_data_axes_grouped():
    s = spec_for_path(".blocks.0.attn.wq", (7168, 7168), LM_RULES, MULTI, MESH_MULTI, BIG)
    assert s == P(("pod", "data"), "model")


def test_divisibility_partial_degrade():
    # dim0 not divisible by model (49155 vocab) -> that dim degrades to None
    s = spec_for_path(".embed", (49155, 1536), LM_RULES, AXES, MESH, BIG)
    assert s == P(None, None)


def test_param_specs_tree(tmp_path):
    """param_specs mirrors an actual arch param tree (single-device mesh)."""
    from repro.archs.recsys import abstract_params
    from repro.configs import get_arch
    from repro.distributed.sharding import param_specs

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_arch("wide-deep").smoke_config()
    specs = param_specs(abstract_params(cfg), "recsys", mesh)
    assert jax.tree_util.tree_structure(specs) == jax.tree_util.tree_structure(
        abstract_params(cfg)
    )
