"""Parity suite for the natively batched SAAT engine.

The batched engine must be indistinguishable from the legacy vmap path
(bit-for-bit on doc ids, fp32 tolerance on scores) and from the exhaustive
oracle at a rank-safe rho — for every scatter_impl, including ragged batches
with zero-weight pad terms and budgets past the total posting count.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact_rho, exhaustive_search, saat_search, saat_search_vmap
from repro.core.saat import max_segments_per_term, saat_plan
from repro.metrics.ir_metrics import rank_overlap

SCATTER_IMPLS = ("jnp", "sort", "pallas")


def _assert_engines_match(index, qt, qw, *, k, rho, impl):
    ms = max_segments_per_term(index)
    b = saat_search(index, qt, qw, k=k, rho=rho, max_segs_per_term=ms, scatter_impl=impl)
    v = saat_search_vmap(index, qt, qw, k=k, rho=rho, max_segs_per_term=ms, scatter_impl=impl)
    np.testing.assert_array_equal(np.asarray(b.doc_ids), np.asarray(v.doc_ids))
    np.testing.assert_allclose(np.asarray(b.scores), np.asarray(v.scores), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(b.postings_processed), np.asarray(v.postings_processed)
    )
    np.testing.assert_array_equal(np.asarray(b.total_postings), np.asarray(v.total_postings))
    return b


@pytest.mark.parametrize("impl", SCATTER_IMPLS)
def test_batched_matches_vmap_budgeted(bm25_index, bm25_queries, impl):
    qt, qw = bm25_queries
    _assert_engines_match(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10, rho=500, impl=impl
    )


@pytest.mark.parametrize("impl", SCATTER_IMPLS)
def test_batched_matches_vmap_and_exhaustive_at_exact_rho(bm25_index, bm25_queries, impl):
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt), jnp.asarray(qw)
    b = _assert_engines_match(
        bm25_index, qt, qw, k=10, rho=exact_rho(bm25_index), impl=impl
    )
    ex = exhaustive_search(bm25_index, qt, qw, k=10)
    np.testing.assert_allclose(np.asarray(b.scores), np.asarray(ex.scores), rtol=1e-3, atol=1e-3)
    assert rank_overlap(np.asarray(b.doc_ids), np.asarray(ex.doc_ids), 10) > 0.99


@pytest.mark.parametrize("impl", SCATTER_IMPLS)
def test_batched_rho_beyond_total_postings(bm25_index, bm25_queries, impl):
    """A budget past every query's postings must stop at each query's total."""
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt[:6]), jnp.asarray(qw[:6])
    rho = exact_rho(bm25_index) * 2
    b = _assert_engines_match(bm25_index, qt, qw, k=10, rho=rho, impl=impl)
    assert (
        np.asarray(b.postings_processed) == np.asarray(b.total_postings)
    ).all()


@pytest.mark.parametrize("impl", SCATTER_IMPLS)
def test_batched_ragged_batch_with_pad_terms(bm25_index, bm25_queries, impl):
    """Rows with mostly zero-weight pad terms ride the same executable."""
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:8]), np.array(qw[:8])
    # make the batch ragged: progressively zero out trailing terms per row
    for i in range(qt.shape[0]):
        keep = max(1, qt.shape[1] - i)
        qw[i, keep:] = 0.0
        qt[i, keep:] = bm25_index.n_terms  # pad slot
    b = _assert_engines_match(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10, rho=2000, impl=impl
    )
    # shorter queries have fewer candidate postings
    totals = np.asarray(b.total_postings)
    assert totals[-1] <= totals[0]


@pytest.mark.parametrize("impl", SCATTER_IMPLS)
def test_batched_all_pad_query_row(bm25_index, bm25_queries, impl):
    """An all-zero-weight row must produce empty results, not garbage."""
    qt, qw = bm25_queries
    qt, qw = np.array(qt[:4]), np.array(qw[:4])
    qw[2] = 0.0
    qt[2] = bm25_index.n_terms
    b = _assert_engines_match(
        bm25_index, jnp.asarray(qt), jnp.asarray(qw), k=10, rho=1000, impl=impl
    )
    assert int(np.asarray(b.total_postings)[2]) == 0
    assert int(np.asarray(b.postings_processed)[2]) == 0
    np.testing.assert_allclose(np.asarray(b.scores)[2], 0.0)


def test_batched_batch_of_one(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    _assert_engines_match(
        bm25_index, jnp.asarray(qt[:1]), jnp.asarray(qw[:1]), k=5, rho=300, impl="jnp"
    )


def test_saat_search_rejects_unbatched_input(bm25_index, bm25_queries):
    qt, qw = bm25_queries
    with pytest.raises(ValueError, match="B, Lq"):
        saat_search(
            bm25_index,
            jnp.asarray(qt[0]),
            jnp.asarray(qw[0]),
            k=5,
            rho=100,
            max_segs_per_term=max_segments_per_term(bm25_index),
        )


def test_batched_plan_matches_single_query_plans(bm25_index, bm25_queries):
    """saat_plan on [B, Lq] == stacking B single-query plans."""
    qt, qw = bm25_queries
    qt, qw = jnp.asarray(qt[:5]), jnp.asarray(qw[:5])
    ms = max_segments_per_term(bm25_index)
    batched = saat_plan(bm25_index, qt, qw, ms)
    for i in range(qt.shape[0]):
        single = saat_plan(bm25_index, qt[i], qw[i], ms)
        np.testing.assert_array_equal(np.asarray(batched.starts[i]), np.asarray(single.starts))
        np.testing.assert_array_equal(np.asarray(batched.cum_len[i]), np.asarray(single.cum_len))
        np.testing.assert_allclose(
            np.asarray(batched.contribs[i]), np.asarray(single.contribs)
        )


def test_max_segments_cached_without_device_sync(bm25_index):
    assert bm25_index.max_segs > 0
    assert max_segments_per_term(bm25_index) == bm25_index.max_segs
    assert bm25_index.max_segs == int(np.asarray(bm25_index.term_seg_count).max())
